"""Asyncio HTTP frontend: submit over HTTP, stream tokens, shut down.

The frontend (:mod:`repro.serving.frontend`) bridges an asyncio HTTP
server to the engine's dedicated thread through a tick-boundary inbox.
This file pins down:

* **Param parsing** — ``params_from_json`` accepts exactly the
  whitelisted scalar fields and ignores everything else.
* **In-process serving** — ``ServerFrontend`` on an ephemeral port:
  ``/healthz`` liveness, ``POST /v1/generate`` streaming NDJSON frames
  whose concatenated tokens are bit-identical to a direct serial-engine
  run of the same prompt, ``POST /v1/cancel`` aborting a mid-flight
  stream with a terminal ``cancelled`` frame, malformed requests
  answered with 400/404 (never a dead connection), and
  ``POST /v1/shutdown`` draining the engine thread (overlapped pipeline
  quiesced) before ``run()`` returns.
* **CLI smoke** — ``python -m repro.launch.serve --server`` end to end
  in a subprocess: parse the printed URL, generate, shut down, exit 0.
  This is the exact flow the CI frontend-smoke step drives.
"""
import dataclasses
import http.client
import json
import os
import re
import subprocess
import sys
import threading

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import (ContinuousEngine, SamplingParams,
                           ServerFrontend, params_from_json)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_params_from_json_whitelist():
    p = params_from_json({"temperature": 0.5, "top_k": 3,
                          "max_new_tokens": 4, "seed": 9,
                          "deadline_s": 2.5,
                          "unknown_field": 1, "stop_ids": [2, 3]})
    assert (p.temperature, p.top_k, p.max_new_tokens, p.seed,
            p.deadline_s) == (0.5, 3, 4, 9, 2.5)
    d = SamplingParams()
    assert p.stop_ids == d.stop_ids            # excluded from the wire
    assert p.top_p == d.top_p                  # absent -> default
    assert params_from_json({}) == d


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _conn(port, timeout=120):
    return http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)


def _post(port, path, obj, timeout=60):
    c = _conn(port, timeout)
    c.request("POST", path, json.dumps(obj),
              {"Content-Type": "application/json"})
    r = c.getresponse()
    body = json.loads(r.read())
    c.close()
    return r.status, body


def _stream(resp):
    """Read NDJSON frames off a chunked response until the terminal one."""
    frames = []
    while True:
        line = resp.readline()
        assert line, "stream ended without a terminal frame"
        frames.append(json.loads(line))
        if frames[-1]["finished"]:
            return frames


def test_server_generate_cancel_shutdown(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, (14,)).tolist()

    # oracle: the serial engine's greedy stream for the same prompt
    serial = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                              prefill_chunk=32, overlap=False)
    rid = serial.submit(prompt, SamplingParams(max_new_tokens=6))
    want = list(serial.run()[rid].token_ids)

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32, overlap=True)
    front = ServerFrontend(eng, port=0)
    started = threading.Event()
    front._port_box = None

    def ready(port):
        front._port_box = port
        started.set()

    t = threading.Thread(target=front.run, args=(ready,), daemon=True)
    t.start()
    assert started.wait(60), "server never came up"
    port = front._port_box

    # liveness
    c = _conn(port, 30)
    c.request("GET", "/healthz")
    r = c.getresponse()
    health = json.loads(r.read())
    assert r.status == 200 and health["ok"]
    c.close()

    # generate: streamed deltas concatenate to the oracle's tokens
    c = _conn(port)
    c.request("POST", "/v1/generate",
              json.dumps({"prompt": prompt, "max_new_tokens": 6}),
              {"Content-Type": "application/json"})
    r = c.getresponse()
    assert r.status == 200
    assert r.getheader("Content-Type") == "application/x-ndjson"
    frames = _stream(r)
    toks = [tok for f in frames for tok in f["tokens"]]
    assert toks == want
    assert frames[-1]["finish_reason"] == "length"
    c.close()

    # cancel a longer request mid-stream: terminal frame says cancelled
    c2 = _conn(port)
    c2.request("POST", "/v1/generate",
               json.dumps({"prompt": prompt, "max_new_tokens": 64}),
               {"Content-Type": "application/json"})
    r2 = c2.getresponse()
    first = json.loads(r2.readline())
    status, body = _post(port, "/v1/cancel",
                         {"request_id": first["request_id"]})
    assert status == 200 and body["cancelled"] is True
    frames = [first] + _stream(r2)
    assert frames[-1]["finish_reason"] == "cancelled"
    got = [tok for f in frames for tok in f["tokens"]]
    assert got == want[:len(got)]              # committed prefix only
    c2.close()

    # malformed requests answer, they don't hang the connection
    assert _post(port, "/v1/generate", {"nope": 1})[0] == 400
    assert _post(port, "/v1/cancel", {})[0] == 400
    assert _post(port, "/v1/nothing", {})[0] == 404

    # clean shutdown: run() returns, engine thread joined and quiesced
    status, body = _post(port, "/v1/shutdown", {})
    assert status == 200 and body["shutting_down"]
    t.join(timeout=120)
    assert not t.is_alive(), "run() did not return after shutdown"
    assert front.loop_thread.error is None
    assert eng._inflight is None and not eng.scheduler.active
    assert front.requests_served == 2


def test_serve_cli_server_smoke():
    """``launch/serve --server`` in a subprocess: the CI smoke path."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "qwen3-0.6b", "--reduced", "--server", "--port", "0",
         "--slots", "2", "--prompt-len", "32", "--steps", "8",
         "--prefill-chunk", "16"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    try:
        port = None
        lines = []
        for line in proc.stdout:
            lines.append(line)
            m = re.search(r"http://127\.0\.0\.1:(\d+)", line)
            if m:
                port = int(m.group(1))
                break
        assert port, "server URL never printed:\n" + "".join(lines)

        c = _conn(port)
        c.request("POST", "/v1/generate",
                  json.dumps({"prompt": list(range(1, 17)),
                              "max_new_tokens": 5}),
                  {"Content-Type": "application/json"})
        frames = _stream(c.getresponse())
        toks = [tok for f in frames for tok in f["tokens"]]
        assert len(toks) == 5
        assert frames[-1]["finish_reason"] == "length"
        c.close()

        assert _post(port, "/v1/shutdown", {})[1]["shutting_down"]
        assert proc.wait(timeout=120) == 0
        rest = proc.stdout.read()
        assert "server drained" in rest
    finally:
        if proc.poll() is None:
            proc.kill()

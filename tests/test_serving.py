"""Serving-path correctness: the frozen-compressed-cache decode must agree
with teacher-forced full forward at zero sparsity, and degrade gracefully at
the paper's (30% K / 50% V) setting."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.distributed import NULL_CTX
from repro.models import lm
from repro.serving import Engine, SamplingParams


def _params_and_prompt(arch, seed=0, b=2, s=64):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jnp.asarray(
        np.random.default_rng(seed).integers(0, cfg.vocab, (b, s)),
        jnp.int32)
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-7b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode(token t) logits == full forward logits at position t."""
    cfg, params, toks = _params_and_prompt(arch)
    eng = Engine(params, cfg, kv_mode="sparse")
    cache, logits_prefill = eng.prefill({"tokens": toks})

    # teacher-forced: full forward over the same prompt
    h = lm.forward_train(params, {"tokens": toks}, cfg, NULL_CTX)
    logits_tf = lm.logits_fn(params, h, cfg, NULL_CTX)
    np.testing.assert_allclose(np.asarray(logits_prefill),
                               np.asarray(logits_tf[:, -1]),
                               rtol=2e-2, atol=2e-2)

    # decode the true next token and compare with teacher forcing at s+1
    nxt = toks[:, -1:]
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    h2 = lm.forward_train(params, {"tokens": toks2}, cfg, NULL_CTX)
    logits_tf2 = lm.logits_fn(params, h2, cfg, NULL_CTX)[:, -1]
    logits_dec, _ = eng._decode(params, cache, nxt)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_tf2),
                               rtol=3e-2, atol=3e-2)


def test_sparse_vs_dense_cache_agree_at_zero_sparsity():
    """Same math up to bf16 accumulation order (the sparse path contracts
    the cache in bf16 with f32 accumulation; the dense path upcasts)."""
    cfg, params, toks = _params_and_prompt("qwen3-0.6b", seed=1)
    e_sparse = Engine(params, cfg, kv_mode="sparse")
    e_dense = Engine(params, cfg, kv_mode="dense")
    cs, ls = e_sparse.prefill({"tokens": toks})
    cd, ld = e_dense.prefill({"tokens": toks})
    np.testing.assert_allclose(np.asarray(ls), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)
    l1, _ = e_sparse._decode(params, cs, toks[:, -1:])
    l2, _ = e_dense._decode(params, cd, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=5e-2, atol=5e-2)
    assert (np.asarray(l1).argmax(-1) == np.asarray(l2).argmax(-1)).all()


def test_paper_kv_sparsity_small_logit_drift():
    """At 30%/50% KV sparsity the decode logits stay close to dense (the
    paper's <1% accuracy-loss regime, measured here as logit agreement)."""
    cfg = get_config("llama3-8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab, (2, 64)), jnp.int32)

    dense_cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0,
                                    kv_v_sparsity=0.0)
    sp_cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5)
    e_d = Engine(params, dense_cfg, kv_mode="sparse")
    e_s = Engine(params, sp_cfg, kv_mode="sparse")
    cache_d, _ = e_d.prefill({"tokens": toks})
    cache_s, _ = e_s.prefill({"tokens": toks})
    nxt = toks[:, -1:]
    ld, _ = e_d._decode(params, cache_d, nxt)
    ls, _ = e_s._decode(params, cache_s, nxt)
    ld, ls = np.asarray(ld), np.asarray(ls)
    cos = (ld * ls).sum() / (np.linalg.norm(ld) * np.linalg.norm(ls))
    # Random-init KV is worst-case for magnitude pruning; the paper's <1%
    # accuracy claim (trained models) is reproduced in benchmarks/bench_kv.
    assert cos > 0.85, f"KV-sparse logits diverged: cos={cos}"


def test_sparse_weights_zero_sparsity_exact():
    """convert_to_sparse at sparsity=0 must be numerically identical."""
    import dataclasses
    from repro.distributed.convert_plan import convert_concrete
    cfg = get_config("qwen3-0.6b").reduced()
    cfg0 = dataclasses.replace(cfg, sparsity=0.0)
    params = lm.init_params(cfg0, jax.random.PRNGKey(3))
    sp = convert_concrete(params, lm.model_specs(cfg0), cfg0, NULL_CTX)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    h1 = lm.forward_train(params, batch, cfg0, NULL_CTX)
    h2 = lm.forward_train(sp, batch, cfg0, NULL_CTX)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_int8_sparse_weights_close():
    import dataclasses
    from repro.distributed.convert_plan import convert_concrete
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, sparsity=0.5)
    params = lm.init_params(cfg, jax.random.PRNGKey(4))
    sp_bf16 = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX)
    sp_int8 = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX,
                               mode="int8")
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    h1 = np.asarray(lm.forward_train(sp_bf16, batch, cfg, NULL_CTX),
                    np.float32)
    h2 = np.asarray(lm.forward_train(sp_int8, batch, cfg, NULL_CTX),
                    np.float32)
    rel = np.abs(h1 - h2).mean() / (np.abs(h1).mean() + 1e-9)
    assert rel < 0.1, rel


def test_generate_multi_step_cache_consistency():
    cfg, params, toks = _params_and_prompt("qwen3-0.6b", seed=5, s=32)
    eng = Engine(params, cfg, kv_mode="sparse")
    out, cache = eng.generate({"tokens": toks},
                              SamplingParams(max_new_tokens=9))
    assert out.shape == (2, 9)
    assert int(cache["pos"]) == 32 + 8

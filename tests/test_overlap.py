"""Overlapped (double-buffered) decode: the token-identity acceptance bar.

``ContinuousEngine(overlap=True)`` dispatches tick t+1's decode/verify
into JAX's async stream *before* syncing tick t's tokens to host; the
single designated sync point is ``_sync_inflight``.  The serial engine
(``overlap=False``) is the oracle — everything here is an identity or
lifecycle claim against it:

* **Token identity** — greedy and seeded-sampled output (tokens AND
  logprobs) of a staggered mixed-prompt wave is bit-identical across
  overlap on/off, for flat and paged pools, with and without speculative
  decoding, with zero steady-state retraces.
* **Lifecycle races** — a cancel or deadline expiry landing while a tick
  is in flight discards the victim's speculatively-dispatched window
  (the ``(slot, rid)`` liveness re-check at commit): the victim's stream
  stays a committed prefix of its solo run, co-tenants are untouched,
  and nothing leaks.
* **Snapshot quiesce** — ``save_snapshot`` drains the in-flight tick
  before serializing the arena, mid-traffic or idle; a warm restart into
  a fresh overlapped engine replays the follow-up wave identically.
* **Shed accounting** — ``Scheduler.shed_count`` is the single counter
  path (``engine.fault_counters["shed"]`` mirrors it, never re-counts)
  and the submit path refreshes the queue-depth gauge, so sheds driven
  through the asyncio frontend's inbox stay consistent.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import (ContinuousEngine, SamplingParams, SpecConfig,
                           stable_trace_counts)


class FakeClock:
    """Injected monotonic clock: tests advance time, nothing sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_prompts(cfg, seed=0, lens=(9, 17, 5, 23, 12)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).tolist() for n in lens]


def _engine(params, cfg, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_tokens", 96)
    kw.setdefault("bs", 16)
    kw.setdefault("prefill_chunk", 32)
    return ContinuousEngine(params, cfg, **kw)


def _staggered_wave(eng, prompts, sp):
    """Submit 2, tick 3 times, submit the rest — forces admissions,
    refreezes, and releases to land while the pipeline holds an
    in-flight record."""
    rids = [eng.submit(p, sp) for p in prompts[:2]]
    for _ in range(3):
        eng.step()
    rids += [eng.submit(p, sp) for p in prompts[2:]]
    out = eng.run()
    return {r: (list(out[r].token_ids), list(out[r].logprobs))
            for r in rids}


def _assert_drained(eng):
    assert eng._inflight is None
    assert not eng.scheduler.active and not eng._blocks
    if eng._alloc is not None:                   # paged conservation
        assert not eng._reserved
        assert not eng._slot_live.any()
        assert int(eng._alloc._ref.sum()) == 0
        assert int(np.asarray(eng.state["refcount"]).sum()) == 0


# ---------------------------------------------------------------------------
# token identity: flat/paged x spec on/off, greedy + seeded sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["flat", "paged"])
@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_overlap_token_identity(setup, spec, paged):
    cfg, params = setup
    prompts = _mixed_prompts(cfg)
    sp = SamplingParams(max_new_tokens=10)
    kw = dict(paged=paged, spec=SpecConfig(k=3) if spec else None)

    serial = _engine(params, cfg, overlap=False, **kw)
    want = _staggered_wave(serial, prompts, sp)

    eng = _engine(params, cfg, overlap=True, **kw)
    got = _staggered_wave(eng, prompts, sp)
    assert got == want, "overlapped output diverged from the serial oracle"

    traces = stable_trace_counts(eng.trace_counts())
    assert all(v <= 1 for v in traces.values()), \
        f"overlap retraced: {traces}"
    if not spec:
        # the chained-decode entry point is live (spec ticks go through
        # verify instead) and compiled exactly once
        assert traces["decode_chain"] == 1
    _assert_drained(eng)


def test_overlap_sampled_identity(setup):
    """Seeded sampling: per-slot RNG lanes advance once per dispatched
    live tick, so draws — including the discarded speculative ones —
    replay exactly."""
    cfg, params = setup
    prompts = _mixed_prompts(cfg, seed=3)
    sp = SamplingParams(max_new_tokens=10, temperature=0.8, top_k=20,
                        seed=7)
    want = _staggered_wave(_engine(params, cfg, overlap=False), prompts, sp)
    eng = _engine(params, cfg, overlap=True)
    assert _staggered_wave(eng, prompts, sp) == want
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# lifecycle races against the in-flight tick
# ---------------------------------------------------------------------------

def _step_until_inflight(eng, rid, min_tokens=2, max_ticks=100):
    """Tick until ``rid`` has committed >= min_tokens AND a dispatched
    window is in flight (so the next lifecycle event races it)."""
    for _ in range(max_ticks):
        eng.step()
        req = next((r for r in eng.scheduler.active.values()
                    if r.rid == rid), None)
        if (req is not None and len(req.generated) >= min_tokens
                and eng._inflight is not None):
            return req
    raise AssertionError("never reached an in-flight state")


def test_overlap_cancel_races_inflight_tick(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, (20,)).tolist()
    pb = rng.integers(0, cfg.vocab, (24,)).tolist()
    sp = SamplingParams(max_new_tokens=8)

    serial = _engine(params, cfg, overlap=False)
    ra = serial.submit(pa, sp)
    rv = serial.submit(pb, sp)
    out = serial.run()
    solo_a, solo_v = list(out[ra].token_ids), list(out[rv].token_ids)

    eng = _engine(params, cfg, overlap=True)
    rw = eng.submit(pa, sp)                      # warmup: populate jit caches
    assert list(eng.run()[rw].token_ids) == solo_a
    warm = stable_trace_counts(eng.trace_counts())
    ra = eng.submit(pa, sp)
    rv = eng.submit(pb, sp)
    victim = _step_until_inflight(eng, rv)
    committed = len(victim.generated)
    # the in-flight record already holds rv's NEXT token; the cancel must
    # discard it — rv's stream ends exactly at what was committed
    assert eng.cancel(rv) is True
    out = eng.run()
    assert out[rv].finish_reason == "cancelled"
    assert len(out[rv].token_ids) == committed
    assert list(out[rv].token_ids) == solo_v[:committed]
    assert list(out[ra].token_ids) == solo_a
    assert eng.fault_counters["cancelled"] == 1
    assert stable_trace_counts(eng.trace_counts()) == warm
    _assert_drained(eng)


def test_overlap_deadline_races_inflight_tick(setup):
    cfg, params = setup
    clk = FakeClock()
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, (20,)).tolist()
    pb = rng.integers(0, cfg.vocab, (24,)).tolist()

    serial = _engine(params, cfg, overlap=False)
    ra = serial.submit(pa, SamplingParams(max_new_tokens=8))
    rb = serial.submit(pb, SamplingParams(max_new_tokens=8))
    out = serial.run()
    solo_a, solo_b = list(out[ra].token_ids), list(out[rb].token_ids)

    eng = _engine(params, cfg, overlap=True, clock=clk)
    ra = eng.submit(pa, SamplingParams(max_new_tokens=8))
    rb = eng.submit(pb, SamplingParams(max_new_tokens=8, deadline_s=5.0))
    victim = _step_until_inflight(eng, rb)
    committed = len(victim.generated)
    clk.t += 10.0                                # expire rb mid-pipeline
    out = eng.run()
    assert out[rb].finish_reason == "timeout"
    # expiry runs at the NEXT tick start, after the pending window (one
    # more token) commits — but never the tokens dispatched beyond it
    assert committed <= len(out[rb].token_ids) <= committed + 1
    assert list(out[rb].token_ids) == solo_b[:len(out[rb].token_ids)]
    assert list(out[ra].token_ids) == solo_a
    assert eng.fault_counters["timeout"] == 1
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# snapshot: save quiesces the pipeline; warm restart replays identically
# ---------------------------------------------------------------------------

def test_overlap_snapshot_quiesces_and_roundtrips(setup, tmp_path):
    cfg, params = setup
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab, (48,)).tolist()
    wave = [shared + rng.integers(0, cfg.vocab, (4,)).tolist()
            for _ in range(2)]
    followup = [shared + rng.integers(0, cfg.vocab, (6,)).tolist()
                for _ in range(2)]
    sp = SamplingParams(max_new_tokens=6)
    snap = str(tmp_path / "snap")

    # oracle: never-restarted serial engine
    serial = _engine(params, cfg, overlap=False, paged=True)
    for p in wave:
        serial.submit(p, sp)
    base_wave = {r: list(o.token_ids) for r, o in serial.run().items()}
    rids = [serial.submit(p, sp) for p in followup]
    res = serial.run()
    base_follow = [list(res[r].token_ids) for r in rids]

    # mid-traffic save: the pipeline holds an in-flight window — saving
    # must quiesce (commit it) before serializing, then serving resumes
    # with identical output
    eng = _engine(params, cfg, overlap=True, paged=True)
    rids = [eng.submit(p, sp) for p in wave]
    for _ in range(4):
        eng.step()
    assert eng._inflight is not None
    step = eng.save_snapshot(snap)
    assert eng._inflight is None                 # quiesced before writing
    out = eng.run()
    assert {r: list(out[r].token_ids) for r in rids} == \
        {r: base_wave[i] for r, i in zip(rids, base_wave)}
    assert step == 1

    # idle save after the drain, then warm restart into a fresh
    # OVERLAPPED engine: follow-up wave token-identical
    eng.save_snapshot(snap)
    n_pages = len(eng._trie)
    fresh = _engine(params, cfg, overlap=True, paged=True)
    assert fresh.load_snapshot(snap) == n_pages
    rids = [fresh.submit(p, sp) for p in followup]
    res = fresh.run()
    assert [list(res[r].token_ids) for r in rids] == base_follow
    _assert_drained(fresh)


# ---------------------------------------------------------------------------
# shed accounting: one counter path, live queue-depth gauge
# ---------------------------------------------------------------------------

def test_shed_single_counter_path_and_queue_gauge(setup):
    from repro.obs import Observability
    cfg, params = setup
    obs = Observability()
    eng = _engine(params, cfg, overlap=True, max_queue=2, obs=obs)
    prompts = _mixed_prompts(cfg)
    sp = SamplingParams(max_new_tokens=4)

    snaps = []
    eng.submit(prompts[0], sp)
    eng.submit(prompts[1], sp)
    assert obs.snapshot()["repro_queue_depth"] == 2.0
    eng.submit(prompts[2], sp, on_token=snaps.append)   # bound hit: shed
    assert [s.finish_reason for s in snaps] == ["shed"]
    # the scheduler owns the authoritative count; the engine mirror and
    # the obs lifecycle counter both re-sync from it (no double count)
    assert eng.scheduler.shed_count == 1
    assert eng.fault_counters["shed"] == eng.scheduler.shed_count
    eng.run()
    assert obs.snapshot()["repro_queue_depth"] == 0.0
    assert obs.snapshot()['repro_lifecycle_events_total{event="shed"}'] \
        == 1.0
    # a second shed wave keeps the mirror exact (assignment, not +=)
    for p in prompts[:2]:
        eng.submit(p, sp)
    eng.submit(prompts[3], sp)                   # bound hit again
    assert eng.scheduler.shed_count == 2
    assert eng.fault_counters["shed"] == 2
    eng.run()
    _assert_drained(eng)
    obs.close()
